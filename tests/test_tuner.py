"""Measured autotuning + the persistent schedule cache.

Acceptance surface of the measured-tuning subsystem: ``autotune="measure"``
selects a schedule by measured time and records per-candidate measured
seconds + model accuracy in ``StencilPlan.candidates``; a second ``plan()``
with the same key is served from the persistent cache without re-timing; a
code-version salt change invalidates the cache; ``cache=False`` disables
persistence; the measured winner still computes correct results.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (RunConfig, StencilProblem, TunedCandidate, plan,
                       tune)
from repro.api import schedule_cache, tuner
from repro.kernels.ref import oracle_run
from repro.core import STENCILS, default_coeffs


def _cfg(cache, **kw):
    kw.setdefault("backend", "engine")
    kw.setdefault("autotune", "measure")
    kw.setdefault("iters_hint", 8)
    kw.setdefault("tune_top_k", 2)
    kw.setdefault("tune_warmup", 1)
    kw.setdefault("tune_repeats", 2)
    return RunConfig(cache=cache, **kw)


def _spy(monkeypatch):
    """Count (and pass through) measured-tuner invocations."""
    calls = []
    real = tuner.measure_candidates

    def counting(problem, config, predictions):
        calls.append(problem.stencil.name)
        return real(problem, config, predictions)

    monkeypatch.setattr(tuner, "measure_candidates", counting)
    return calls


# --- measured selection (acceptance criterion) --------------------------------

@pytest.mark.parametrize("name,dims", [
    ("diffusion2d", (64, 512)),
    ("hotspot3d", (12, 72, 72)),
])
def test_measure_selects_by_time_and_records(name, dims, tmp_path):
    p = plan(StencilProblem(name, dims), _cfg(str(tmp_path / "s.json")))
    assert not p.tuned_from_cache
    assert len(p.candidates) == 2
    for c in p.candidates:
        assert isinstance(c, TunedCandidate)
        assert c.measured_s > 0 and c.measured_run_time > 0
        assert c.model_accuracy > 0
        assert not c.from_cache
    per_iter = [c.s_per_iter for c in p.candidates]
    assert per_iter == sorted(per_iter), \
        "candidates ranked by amortized per-iteration measured time"
    assert p.geometry.par_time == p.candidates[0].geom.par_time
    assert p.geometry.bsize == p.candidates[0].geom.bsize


def test_measured_winner_runs_correctly(tmp_path):
    st = STENCILS["diffusion2d"]
    g = jax.random.uniform(jax.random.PRNGKey(3), (48, 320), jnp.float32,
                           0.5, 2.0)
    c = default_coeffs(st)
    p = plan(StencilProblem("diffusion2d", (48, 320)),
             _cfg(str(tmp_path / "s.json")))
    np.testing.assert_allclose(np.asarray(p.run(g, 5, c)),
                               np.asarray(oracle_run(st, g, c, 5)),
                               rtol=2e-5, atol=2e-5)


# --- cache behavior (acceptance criterion) ------------------------------------

def test_cache_hit_skips_retiming(tmp_path, monkeypatch):
    calls = _spy(monkeypatch)
    cfg = _cfg(str(tmp_path / "s.json"))
    problem = StencilProblem("diffusion2d", (64, 512))
    p1 = plan(problem, cfg)
    assert calls == ["diffusion2d"] and not p1.tuned_from_cache
    p2 = plan(problem, cfg)
    assert calls == ["diffusion2d"], "second plan() must not re-time"
    assert p2.tuned_from_cache
    assert p2.geometry == p1.geometry
    (cached,) = p2.candidates
    assert cached.from_cache
    assert cached.measured_s == pytest.approx(p1.candidates[0].measured_s)
    assert cached.model_accuracy == pytest.approx(
        p1.candidates[0].model_accuracy)


def test_salt_change_invalidates(tmp_path, monkeypatch):
    calls = _spy(monkeypatch)
    cfg = _cfg(str(tmp_path / "s.json"))
    problem = StencilProblem("diffusion2d", (64, 512))
    monkeypatch.setattr(schedule_cache, "code_version_salt", lambda: "aaaa")
    plan(problem, cfg)
    assert plan(problem, cfg).tuned_from_cache and len(calls) == 1
    # editing kernel sources changes the salt -> the cached winner is stale
    monkeypatch.setattr(schedule_cache, "code_version_salt", lambda: "bbbb")
    p = plan(problem, cfg)
    assert not p.tuned_from_cache and len(calls) == 2


def test_key_differs_per_backend_device_and_pin(tmp_path):
    problem = StencilProblem("diffusion2d", (64, 512))
    dev = RunConfig().resolved_device()
    base = schedule_cache.schedule_key(problem, _cfg(None), dev, 1, None)
    for other_cfg, other_dev in [
            (_cfg(None, backend="pallas_interpret"), dev),
            (_cfg(None, par_time=4), dev),
            (_cfg(None, bsize=256), dev),
            (_cfg(None), RunConfig(device="tpu_v5p").resolved_device())]:
        assert schedule_cache.schedule_key(
            problem, other_cfg, other_dev, 1, None) != base
    # iters_hint deliberately does NOT key the cache (per-super-step timing)
    assert schedule_cache.schedule_key(
        problem, _cfg(None, iters_hint=999), dev, 1, None) == base
    # interpret-mode timings must never serve compiled plans (or vice versa)
    assert schedule_cache.schedule_key(
        problem, _cfg(None, interpret=True), dev, 1, None) != base
    # sweep-constraining knobs key the cache: a winner tuned under a loose
    # par_time_max must not be served to (and violate) a tighter one
    assert schedule_cache.schedule_key(
        problem, _cfg(None, par_time_max=8), dev, 1, None) != base
    assert schedule_cache.schedule_key(
        problem, _cfg(None, tune_top_k=8), dev, 1, None) != base


def test_key_fingerprints_user_stencils_beyond_name():
    """Two different stencils under one name must not share a cache entry."""
    from repro.core.stencils import Stencil
    cheap = Stencil("mystencil", 2, 1, 1, 1, 1, False, ("c",),
                    lambda get, c, aux=None: c["c"] * get((0, 0)))
    heavy = Stencil("mystencil", 2, 1, 5, 1, 1, False, ("c",),
                    lambda get, c, aux=None: c["c"] * (
                        get((0, 1)) + get((0, -1)) + get((1, 0))))
    dev = RunConfig().resolved_device()
    keys = [schedule_cache.schedule_key(
        StencilProblem(st, (32, 160)), _cfg(None), dev, 1, None)
        for st in (cheap, heavy)]
    assert keys[0] != keys[1]


def test_unwritable_cache_warns_instead_of_discarding_tune(tmp_path):
    # a regular file as a path component makes mkdir fail even for root
    (tmp_path / "blocker").write_text("")
    bad = tmp_path / "blocker" / "s.json"
    with pytest.warns(RuntimeWarning, match="not persisted"):
        schedule_cache.ScheduleCache(bad).put("k", {"par_time": 2})
    # and plan() itself survives: winner is returned, nothing persisted
    with pytest.warns(RuntimeWarning, match="not persisted"):
        p = plan(StencilProblem("diffusion2d", (64, 512)), _cfg(str(bad)))
    assert p.geometry is not None and not p.tuned_from_cache


def test_mangled_cache_entry_is_a_miss_not_a_crash(tmp_path, monkeypatch):
    calls = _spy(monkeypatch)
    path = str(tmp_path / "s.json")
    cfg = _cfg(path)
    problem = StencilProblem("diffusion2d", (64, 512))
    plan(problem, cfg)
    # hand-edit the (documented human-editable) entry into garbage
    cache = schedule_cache.ScheduleCache(path)
    dev = cfg.resolved_device()
    key = schedule_cache.schedule_key(problem, cfg, dev, 1, None)
    for bad in ({"par_time": "soon", "note": "hand-edited"},
                {"par_time": 0, "bsize": [256], "measured_s": 0.1,
                 "model_accuracy": 1.0},          # ceil(iters/0) would crash
                {"par_time": 2, "bsize": [256, 256], "measured_s": 0.1,
                 "model_accuracy": 1.0}):         # wrong rank for a 2D grid
        cache.put(key, bad)
        n = len(calls)
        p = plan(problem, cfg)
        assert not p.tuned_from_cache and len(calls) == n + 1, \
            f"mangled entry {bad} must fall through to re-tuning"
    assert plan(problem, cfg).tuned_from_cache   # re-tune healed the entry


def test_cache_false_disables_persistence(tmp_path, monkeypatch):
    calls = _spy(monkeypatch)
    monkeypatch.setenv("REPRO_SCHEDULE_CACHE",
                       str(tmp_path / "untouched.json"))
    cfg = _cfg(False)
    problem = StencilProblem("diffusion2d", (64, 512))
    plan(problem, cfg)
    plan(problem, cfg)
    assert len(calls) == 2, "no cache -> every plan re-times"
    assert not (tmp_path / "untouched.json").exists()


def test_default_path_honors_env(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path / "env.json"))
    assert schedule_cache.default_cache_path() == tmp_path / "env.json"


def test_cache_file_is_versioned_json_and_corruption_safe(tmp_path):
    path = tmp_path / "s.json"
    cache = schedule_cache.ScheduleCache(path)
    assert cache.get("k") is None            # missing file: miss, no crash
    cache.put("k", {"par_time": 4, "bsize": [256], "measured_s": 0.1,
                    "model_accuracy": 1.0})
    data = json.loads(path.read_text())
    assert data["version"] == schedule_cache.CACHE_FORMAT_VERSION
    assert cache.get("k")["par_time"] == 4
    path.write_text("{not json")             # corrupt: miss, then self-heal
    assert cache.get("k") is None
    cache.put("k2", {"par_time": 2})
    assert cache.get("k2")["par_time"] == 2


def test_tune_helper_forces_measure_mode(tmp_path):
    p = tune(StencilProblem("diffusion2d", (64, 512)),
             RunConfig(backend="engine", iters_hint=8, tune_top_k=2,
                       tune_repeats=2),
             cache=str(tmp_path / "s.json"))
    assert p.config.autotune == "measure"
    assert isinstance(p.candidates[0], TunedCandidate)
    # a redundant autotune= override must not crash replace()
    p2 = tune(StencilProblem("diffusion2d", (64, 512)),
              RunConfig(backend="engine", iters_hint=8, tune_top_k=1,
                        tune_repeats=1), autotune="measure",
              cache=str(tmp_path / "s.json"))
    assert p2.config.autotune == "measure"


# --- config surface -----------------------------------------------------------

def test_autotune_bool_aliases():
    assert RunConfig(autotune=True).autotune == "model"
    assert RunConfig(autotune=False).autotune is False
    assert RunConfig(autotune="measure").autotune == "measure"
    with pytest.raises(ValueError, match="autotune"):
        RunConfig(autotune="fastest")


def test_tuning_knob_validation():
    with pytest.raises(ValueError, match="tune_top_k"):
        RunConfig(tune_top_k=0)
    with pytest.raises(ValueError, match="tune_warmup"):
        RunConfig(tune_warmup=-1)
    with pytest.raises(ValueError, match="tune_iters"):
        RunConfig(tune_iters=0)
