"""System tests: fault tolerance, checkpointing, data pipeline, compression.

These exercise the 1000-node substrate pieces at toy scale:
  * checkpoint atomicity / integrity / retention,
  * fault_tolerant_train restart + failure retry + straggler detection,
  * elastic re-mesh (restore onto a different mesh),
  * stateless data addressing (restart-exactness),
  * error-feedback gradient compression invariants.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, save_pytree
from repro.data import DataConfig, SyntheticLMDataset, prefetch
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         ef_compress_update, init_ef_state)
from repro.optim.adamw import AdamWConfig
from repro.train import TrainLoopConfig, fault_tolerant_train


def _toy_state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(k, (8, 8)),
              "b": jnp.zeros((8,), jnp.bfloat16)}
    return params


# --- checkpointing -----------------------------------------------------------

def test_checkpoint_roundtrip_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=2)
    params = _toy_state()
    for s in (1, 5, 9):
        mgr.save_async({"params": params, "step": jnp.asarray(s)}, s)
    mgr.wait()
    assert latest_step(d) == 9
    # retention: only the last two steps remain
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    assert steps == [5, 9]
    restored, step = mgr.restore_latest({"params": params,
                                         "step": jnp.zeros(())})
    assert step == 9
    np.testing.assert_array_equal(restored["params"]["w"], params["w"])
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_checkpoint_ignores_incomplete_tmp(tmp_path):
    d = str(tmp_path / "ck")
    params = _toy_state()
    save_pytree({"p": params}, d, 3)
    # a crashed save leaves a .tmp dir: must not shadow the good step
    os.makedirs(os.path.join(d, "step_00000007.tmp"))
    assert latest_step(d) == 3


def test_checkpoint_integrity_check(tmp_path):
    from repro.checkpoint import restore_pytree
    d = str(tmp_path / "ck")
    params = _toy_state()
    save_pytree({"p": params}, d, 1)
    shard = os.path.join(d, "step_00000001", "shard_00000.npz")
    data = dict(np.load(shard))
    key = list(data)[0]
    data[key] = data[key] + 1.0
    np.savez(shard, **data)
    with pytest.raises(IOError, match="integrity"):
        restore_pytree({"p": params}, d, 1)


# --- fault-tolerant loop -----------------------------------------------------

def _toy_train(tmp_path, total_steps, failure_hook=None):
    cfg = AdamWConfig(lr=5e-2, warmup_steps=2, total_steps=total_steps,
                      weight_decay=0.0)

    def step_fn(params, opt, batch):
        def loss_fn(p):
            pred = batch["x"] @ p["w"]
            return jnp.mean((pred - batch["y"]) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, m = adamw_update(cfg, params, g, opt)
        m["loss"] = loss
        return params, opt, m

    rng = np.random.default_rng(0)
    W = rng.standard_normal((8, 8)).astype(np.float32)

    def batch_at(s):
        r = np.random.default_rng(s)
        x = r.standard_normal((16, 8)).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(x @ W)}

    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    opt = adamw_init(params)
    loop = TrainLoopConfig(total_steps=total_steps, checkpoint_every=5,
                           checkpoint_dir=str(tmp_path / "ck"),
                           straggler_factor=50.0)
    return fault_tolerant_train(loop, step_fn, (params, opt),
                                iter(()), batch_at,
                                failure_hook=failure_hook,
                                log=lambda *_: None)


def test_loop_trains_and_checkpoints(tmp_path):
    params, opt, ev = _toy_train(tmp_path, 20)
    assert np.mean(ev["losses"][-3:]) < np.mean(ev["losses"][:3])
    assert latest_step(str(tmp_path / "ck")) == 19


def test_loop_recovers_from_injected_failure(tmp_path):
    boom = {7}

    def failure_hook(s):
        if s in boom:
            boom.remove(s)
            raise RuntimeError("simulated device loss")

    params, opt, ev = _toy_train(tmp_path, 12, failure_hook=failure_hook)
    assert ev["retries"] == 1
    assert len(ev["losses"]) >= 12         # re-ran steps from last checkpoint


def test_loop_restart_resumes_from_checkpoint(tmp_path):
    # first run writes checkpoints
    _toy_train(tmp_path, 8)
    ck = latest_step(str(tmp_path / "ck"))
    assert ck is not None
    # second run: resumes at ck+1, executes only the remainder
    params, opt, ev = _toy_train(tmp_path, 14)
    assert len(ev["losses"]) == 14 - (ck + 1)


def test_data_pipeline_stateless_and_host_sharded():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=3)
    a = SyntheticLMDataset(cfg).batch_at(5)
    b = SyntheticLMDataset(cfg).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # restart-exact
    # host sharding partitions the global batch deterministically
    h0 = SyntheticLMDataset(cfg, host_id=0, num_hosts=2).batch_at(5)
    h1 = SyntheticLMDataset(cfg, host_id=1, num_hosts=2).batch_at(5)
    assert h0["tokens"].shape[0] == 4 and h1["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_prefetch_preserves_order():
    it = prefetch(iter([{"i": i} for i in range(6)]), depth=2)
    assert [b["i"] for b in it] == list(range(6))


# --- elastic re-mesh ---------------------------------------------------------

def test_elastic_remesh_roundtrip():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.train.loop import reshard_for_mesh
    params = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mesh1 = make_mesh((1, 1), ("data", "model"))
    spec = {"w": P(None, None)}
    out = reshard_for_mesh(params, mesh1, spec)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(params["w"]))


# --- optimizer + compression -------------------------------------------------

def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, s)) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] > lrs[3] > lrs[4]          # cosine decay
    assert abs(lrs[4] - 0.1) < 1e-6          # floor


def test_adamw_clips_global_norm():
    cfg = AdamWConfig(lr=1e-2, clip_norm=1.0, warmup_steps=0,
                      weight_decay=0.0)
    params = {"w": jnp.zeros((4, 4))}
    opt = adamw_init(params)
    g = {"w": jnp.full((4, 4), 1e6)}
    new_params, _, metrics = adamw_update(cfg, params, g, opt)
    assert float(metrics["grad_norm"]) > 1.0          # raw norm reported
    # clipped update: param step bounded by ~lr regardless of grad scale
    assert float(jnp.max(jnp.abs(new_params["w"]))) < 10 * cfg.lr


def test_ef_compression_error_feedback():
    """Residual carries the dropped mass: kept + residual == grads."""
    params = {"w": jnp.zeros((4, 4))}
    g = {"w": jnp.arange(1.0, 17.0).reshape(4, 4)}   # distinct magnitudes
    ef = init_ef_state(params)
    kept, ef2, wire = ef_compress_update(g, ef, keep_ratio=0.25,
                                         quantize=False)
    recon = kept["w"].astype(jnp.float32) + ef2["w"]
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g["w"]),
                               rtol=1e-6)
    # compression actually sparsifies: only the top-4 magnitudes kept
    assert float((kept["w"] != 0).sum()) <= 4
    # EF invariant over any horizon: delivered + residual == sum of grads
    total = jnp.zeros((4, 4))
    ef = init_ef_state(params)
    n = 16
    for _ in range(n):
        kept, ef, _ = ef_compress_update(g, ef, keep_ratio=0.25,
                                         quantize=False)
        total = total + kept["w"]
    np.testing.assert_allclose(np.asarray(total + ef["w"]),
                               n * np.asarray(g["w"]), rtol=1e-5)
    # and the delivered mass is a growing fraction of the target (no leak)
    assert float(jnp.sum(total)) > 0.7 * n * float(jnp.sum(g["w"]))


def test_compressed_train_step_converges():
    """EF-compressed training reaches a comparable loss to exact training
    on a tiny LM (the cross-pod DCN trick preserves convergence)."""
    from repro.data import DataConfig, SyntheticLMDataset
    from repro.models import ModelConfig, init_params
    from repro.train import make_compressed_train_step, make_train_step

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv=1, d_head=16, d_ff=64, vocab=64,
                      tie_embeddings=True, attn_q_chunk=16, attn_kv_chunk=16,
                      loss_chunk=16)
    data = SyntheticLMDataset(DataConfig(vocab=64, seq_len=32,
                                         global_batch=4))
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=30)

    exact = jax.jit(make_train_step(cfg, ocfg))
    comp = jax.jit(make_compressed_train_step(cfg, ocfg, keep_ratio=0.2))

    pe = init_params(jax.random.PRNGKey(0), cfg)
    pc = jax.tree.map(lambda x: x, pe)
    oe = adamw_init(pe)
    oc = (adamw_init(pc), comp.init_extra(pc))
    le = lc = None
    for s in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        pe, oe, me = exact(pe, oe, batch)
        pc, oc, mc = comp(pc, oc, batch)
        le, lc = float(me["loss"]), float(mc["loss"])
    assert mc["compressed_wire_bytes"] > 0
    # compressed training tracks exact within a reasonable factor
    assert lc < 1.3 * le + 0.5, (lc, le)


def test_loop_detects_stragglers(tmp_path):
    """A step much slower than the rolling median is recorded and triggers
    an early checkpoint."""
    import time as _time
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=15)

    def step_fn(params, opt, batch):
        def loss_fn(p):
            return jnp.mean((batch["x"] @ p["w"]) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, m = adamw_update(cfg, params, g, opt)
        m["loss"] = loss
        return params, opt, m

    def batch_at(s):
        r = np.random.default_rng(s)
        return {"x": jnp.asarray(r.standard_normal((4, 8)), jnp.float32)}

    def slow_hook(s):
        if s == 10:
            _time.sleep(0.6)        # simulated slow host (inside timing)

    params = {"w": jnp.ones((8, 8), jnp.float32)}
    opt = adamw_init(params)
    loop = TrainLoopConfig(total_steps=15, checkpoint_every=100,
                           checkpoint_dir=str(tmp_path / "ck"),
                           straggler_factor=5.0, straggler_window=20)
    _, _, ev = fault_tolerant_train(loop, step_fn, (params, opt), iter(()),
                                    batch_at, failure_hook=slow_hook,
                                    log=lambda *_: None)
    assert any(s == 10 for s, _, _ in ev["stragglers"]), ev["stragglers"]
    # early checkpoint was written
    assert latest_step(str(tmp_path / "ck")) is not None
