"""Resilience-layer tests: deterministic fault injection, health guards,
retry/breaker policy, serving chaos matrix, checkpointed-run resume.

The chaos matrix drives the *whole serving stack* once per registered
injection point with a transient fault installed, and asserts the two
operational invariants the layer exists for: the service never hangs
(every workload runs under an asyncio timeout) and never silently drops a
request (metrics conservation after drain:
``submitted == completed + rejected + failed`` and ``in_flight == 0``).
The SIGKILL test crashes a real subprocess mid-checkpoint-save and asserts
the resumed run's final grid is bit-identical to an uninterrupted one.
"""
import asyncio
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint  # noqa: F401 — registers the checkpoint.* points
import repro.core.distributed  # noqa: F401 — registers its injection point
from repro.api import RunConfig, StencilProblem, plan
from repro.api.schedule_cache import ScheduleCache
from repro.resilience import (BreakerConfig, CircuitBreaker, FaultPlan,
                              FaultSpec, HealthPolicy, InjectedFault,
                              NumericalFault, RetryPolicy, active_plan,
                              corrupt_point, fault_point, registered_points,
                              run_checkpointed)
from repro.resilience.health import CheckpointMismatch
from repro.serve import (LaunchFailed as ServeLaunchFailed,
                         NumericalFault as ServeNumericalFault,
                         ServiceConfig, ServiceOverloaded, StencilRequest,
                         StencilService)

SHAPE = (12, 32)
RUN = {"backend": "engine", "par_time": 2, "bsize": 16, "cache": False}
BUCKET = {"problem": {"stencil": "diffusion2d", "shape": list(SHAPE)},
          "run": dict(RUN), "max_batch": 4, "max_wait_ms": 1.0,
          "queue_cap": 16}
FAST_RETRY = {"max_attempts": 2, "base_backoff_s": 0.001}

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


@pytest.fixture(autouse=True)
def no_leaked_plan():
    """A test that forgets to uninstall its plan must not poison the rest
    of the suite."""
    yield
    p = active_plan()
    if p is not None:
        p.uninstall()


def subprocess_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return env


def run_async(coro, timeout=120.0):
    """Every serving workload runs under a hard timeout: 'the service never
    hangs' is an assertion here, not a hope."""
    async def guarded():
        return await asyncio.wait_for(coro, timeout)
    return asyncio.run(guarded())


def assert_conserved(snap):
    assert snap["in_flight"] == 0, snap
    assert snap["submitted"] == (snap["completed"] + snap["rejected_total"]
                                 + snap["failed_total"]), snap


def grid_for(seed=0, shape=SHAPE):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape,
                              jnp.float32, 0.5, 2.0)


# --- fault plans: determinism ------------------------------------------------

class TestFaultPlan:
    def test_unknown_point_rejected_strict(self):
        with pytest.raises(ValueError, match="unknown injection point"):
            FaultPlan([FaultSpec("no.such.seam")]).install()
        FaultPlan([FaultSpec("no.such.seam")], strict=False).install() \
            .uninstall()

    def test_nth_fires_exactly_once_and_replays(self):
        spec = FaultSpec("serve.launch", nth=3)
        plan_ = FaultPlan([spec])
        for _ in range(2):                      # reinstall replays identically
            with plan_.active():
                fired_at = []
                for i in range(1, 6):
                    try:
                        fault_point("serve.launch")
                    except InjectedFault:
                        fired_at.append(i)
                assert fired_at == [3]
                assert plan_.calls("serve.launch") == 5

    def test_probability_stream_is_deterministic(self):
        def fires(seed):
            out = []
            with FaultPlan([FaultSpec("serve.launch", p=0.3,
                                      max_fires=None)],
                           seed=seed).active():
                for i in range(50):
                    try:
                        fault_point("serve.launch")
                    except InjectedFault:
                        out.append(i)
            return out
        a, b, c = fires(7), fires(7), fires(8)
        assert a == b                       # same seed: identical firings
        assert a != c                       # different seed: different stream
        assert 0 < len(a) < 50              # actually probabilistic

    def test_match_predicate_pins_the_target(self):
        with FaultPlan([FaultSpec("serve.launch", max_fires=None,
                                  match=lambda ctx: 3 in ctx.get("seqs", ())),
                        ]).active() as p:
            fault_point("serve.launch", {"seqs": (1, 2)})      # no fire
            with pytest.raises(InjectedFault):
                fault_point("serve.launch", {"seqs": (3, 4)})
            assert [f[2] for f in p.fired] == [2]

    def test_corrupt_point_poisons_requested_member(self):
        v = jnp.ones((3, 4, 5))
        with FaultPlan([FaultSpec("backend.execute_batch.result",
                                  action="nan", member=1)]).active():
            out = corrupt_point("backend.execute_batch.result", v)
        out = np.asarray(out)
        assert np.isnan(out[1]).sum() == 1
        assert not np.isnan(out[0]).any() and not np.isnan(out[2]).any()
        # member rows other than the poisoned cell are bit-intact
        assert (out[1].ravel()[1:] == 1.0).all()

    def test_registry_covers_the_hot_seams(self):
        pts = registered_points()
        for want in ("backend.execute", "backend.execute_batch",
                     "backend.execute_batch.result", "exec_cache.get",
                     "schedule_cache.get", "schedule_cache.put",
                     "serve.launch", "checkpoint.save", "checkpoint.restore",
                     "distributed.exchange"):
            assert want in pts, f"{want} missing from {sorted(pts)}"


# --- health / retry / breaker policy -----------------------------------------

class TestHealthPolicy:
    def test_detects_nan_inf_blowup(self):
        g = np.ones((4, 4), np.float32)
        pol = HealthPolicy(max_abs=10.0)
        assert pol.fault_of(g) is None
        assert pol.fault_of(np.where(np.eye(4) > 0, np.nan, g)).kind == "nan"
        assert pol.fault_of(np.where(np.eye(4) > 0, np.inf, g)).kind == "inf"
        f = pol.fault_of(g * 100.0)
        assert f.kind == "blowup" and f.max_abs == pytest.approx(100.0)

    def test_bf16_and_member_tagging(self):
        import ml_dtypes
        g = np.ones((4,), ml_dtypes.bfloat16)
        assert HealthPolicy().fault_of(g) is None
        g[2] = np.nan
        f = HealthPolicy().fault_of(g, member=5)
        assert f is not None and f.member == 5 and "member 5" in str(f)

    def test_disabled_is_a_noop(self):
        g = np.full((2, 2), np.nan, np.float32)
        assert HealthPolicy.make(False).fault_of(g) is None
        HealthPolicy.make(False).check(g)           # no raise

    def test_check_raises(self):
        with pytest.raises(NumericalFault):
            HealthPolicy().check(np.array([np.inf], np.float32))


class TestRetryAndBreaker:
    def test_backoff_caps(self):
        pol = RetryPolicy(max_attempts=5, base_backoff_s=0.1,
                          max_backoff_s=0.35)
        assert [pol.backoff_s(k) for k in (1, 2, 3, 4)] == \
            pytest.approx([0.1, 0.2, 0.35, 0.35])
        assert RetryPolicy.make(False).max_attempts == 1

    def test_breaker_state_machine(self):
        cb = CircuitBreaker(BreakerConfig(fail_threshold=2, open_threshold=2,
                                          recovery_successes=2,
                                          open_cooldown_s=5.0))
        t = 0.0
        assert cb.mode(t) == "closed"
        cb.on_failure(t); cb.on_failure(t)
        assert cb.mode(t) == "degraded"
        cb.on_failure(t)
        assert cb.mode(t) == "degraded"             # threshold not reached
        cb.on_failure(t)
        assert cb.mode(t) == "open" and not cb.admits(t)
        assert cb.retry_after_s(t) == pytest.approx(5.0)
        # cooldown elapses: probe traffic again (degraded)
        assert cb.mode(6.0) == "degraded" and cb.admits(6.0)
        cb.on_success(6.0)
        assert cb.mode(6.0) == "degraded"
        cb.on_success(6.1)
        assert cb.mode(6.1) == "closed"
        # a success resets the failure streak
        cb.on_failure(7.0); cb.on_success(7.1); cb.on_failure(7.2)
        assert cb.mode(7.2) == "closed"
        assert [s for s, _ in cb.transitions] == \
            ["degraded", "open", "degraded", "closed"]


# --- serving: quarantine, bisection, breaker, chaos matrix -------------------

def service_config(**kw):
    spec = dict(buckets=[dict(BUCKET)], retry=dict(FAST_RETRY))
    spec.update(kw)
    return ServiceConfig.make(spec)


async def run_workload(svc, n=6, iters=(2, 4), seed0=0):
    reqs = [StencilRequest("diffusion2d", grid_for(seed0 + i),
                           iters[i % len(iters)]) for i in range(n)]
    futs = [svc.submit_nowait(r) for r in reqs]
    return await asyncio.gather(*futs, return_exceptions=True)


class TestServingResilience:
    def test_nan_member_is_quarantined_neighbors_bit_identical(self):
        async def main():
            svc = await StencilService(service_config()).start(prewarm=False)
            # fault-free reference results, one per seed
            clean = await run_workload(svc, n=3, iters=(4,))
            fplan = FaultPlan([FaultSpec("backend.execute_batch.result",
                                         action="nan", nth=1, member=1)])
            with fplan.active():
                res = await run_workload(svc, n=3, iters=(4,))
            snap = svc.snapshot()
            await svc.stop()
            return clean, res, snap, svc.snapshot()
        clean, res, snap, final = run_async(main())
        assert all(isinstance(r, type(clean[0])) for r in clean)
        assert isinstance(res[1], ServeNumericalFault)
        assert isinstance(res[1], NumericalFault)       # resilience family
        assert res[1].kind == "nan" and res[1].member == 1
        # the two healthy members rode the SAME poisoned launch and are
        # bit-identical to the fault-free run
        assert res[0].batch_size == 3
        for i in (0, 2):
            assert (np.asarray(res[i].grid)
                    == np.asarray(clean[i].grid)).all()
        assert snap["failed"]["numerical_fault"] == 1
        assert snap["quarantined"] == 1
        assert_conserved(final)

    def test_bisection_isolates_the_poison_request(self):
        async def main():
            svc = await StencilService(service_config()).start(prewarm=False)
            # every launch whose member set contains seq 3 fails forever:
            # bisection must corner seq 3 alone and serve the rest
            fplan = FaultPlan([FaultSpec(
                "serve.launch", max_fires=None,
                match=lambda ctx: 3 in ctx.get("seqs", ()))])
            with fplan.active():
                res = await run_workload(svc, n=4, iters=(4,))
            snap = svc.snapshot()
            await svc.stop()
            return res, snap, svc.snapshot()
        res, snap, final = run_async(main())
        kinds = [type(r).__name__ for r in res]
        assert kinds[2] == "LaunchFailed", kinds        # seq 3 = 3rd request
        assert isinstance(res[2], ServeLaunchFailed)
        assert res[2].attempts >= 2                     # retry budget spent
        ok = [r for i, r in enumerate(res) if i != 2]
        assert all(not isinstance(r, Exception) for r in ok)
        assert snap["failed"]["launch_failed"] == 1
        assert snap["retries"] >= 1
        assert_conserved(final)

    def test_transient_fault_is_retried_away(self):
        async def main():
            svc = await StencilService(service_config()).start(prewarm=False)
            with FaultPlan([FaultSpec("exec_cache.get", nth=1)]).active():
                res = await run_workload(svc, n=3, iters=(4,))
            await svc.stop()
            return res, svc.snapshot()
        res, snap = run_async(main())
        assert all(not isinstance(r, Exception) for r in res)
        assert snap["retries"] >= 1 and snap["failed_total"] == 0
        assert_conserved(snap)

    def test_breaker_degrades_opens_and_recovers(self):
        offset = [0.0]

        def clock():
            return time.monotonic() + offset[0]

        async def main():
            cfg = service_config(
                retry={"max_attempts": 1},
                breaker={"fail_threshold": 1, "open_threshold": 1,
                         "recovery_successes": 1, "open_cooldown_s": 30.0})
            svc = await StencilService(cfg, clock=clock).start(prewarm=False)
            name = svc.config.buckets[0].name
            always = FaultPlan([FaultSpec("serve.launch", p=1.0,
                                          max_fires=None)])
            with always.active():
                r1 = await asyncio.gather(
                    svc.submit_nowait(
                        StencilRequest("diffusion2d", grid_for(0), 2)),
                    return_exceptions=True)
                assert svc.snapshot()["breaker"][name] == "degraded"
                r2 = await asyncio.gather(
                    svc.submit_nowait(
                        StencilRequest("diffusion2d", grid_for(1), 2)),
                    return_exceptions=True)
                assert svc.snapshot()["breaker"][name] == "open"
                # open: admission rejects with retry-after
                with pytest.raises(ServiceOverloaded) as ei:
                    svc.submit_nowait(
                        StencilRequest("diffusion2d", grid_for(2), 2))
                assert ei.value.retry_after_s > 0
            # cooldown elapses (fault gone): probe succeeds, breaker closes
            offset[0] += 31.0
            ok = await svc.submit(
                StencilRequest("diffusion2d", grid_for(3), 2))
            snap = svc.snapshot()
            await svc.stop()
            return r1, r2, ok, snap, name, svc.snapshot()
        r1, r2, ok, snap, name, final = run_async(main())
        assert isinstance(r1[0], ServeLaunchFailed)
        assert isinstance(r2[0], ServeLaunchFailed)
        assert ok.iters == 2
        assert snap["breaker"][name] == "closed"
        assert snap["rejected"]["breaker"] == 1
        assert_conserved(final)

    def test_checkpointed_request_survives_service_kill_cycle(self, tmp_path):
        """Serving-side checkpointing: a request whose service 'dies'
        mid-run (simulated by a transient launch abort) is resubmitted with
        the same key and resumes instead of recomputing — and the final
        grid is bit-identical to an uncheckpointed run."""
        ckroot = str(tmp_path / "serve-ck")

        async def main():
            cfg = service_config(checkpoint_dir=ckroot)
            svc = await StencilService(cfg).start(prewarm=False)
            g = grid_for(0)
            want = await svc.submit(StencilRequest("diffusion2d", g, 8))
            req = dict(problem="diffusion2d", grid=g, iters=8,
                       checkpoint_key="job-1", checkpoint_every=2)
            # first attempt dies after two chunks (raise at the 3rd save;
            # no retry budget -> surfaces as LaunchFailed)
            fplan = FaultPlan([FaultSpec("checkpoint.save", nth=3,
                                         max_fires=None)])
            svc2 = await StencilService(service_config(
                checkpoint_dir=ckroot,
                retry={"max_attempts": 1})).start(prewarm=False)
            with fplan.active():
                res1 = await asyncio.gather(
                    svc2.submit_nowait(StencilRequest(**req)),
                    return_exceptions=True)
            # resubmission with the same key resumes from step 4
            res2 = await svc2.submit(StencilRequest(**req))
            snap2 = svc2.snapshot()
            await svc.stop()
            await svc2.stop()
            return want, res1, res2, snap2, svc2.snapshot()
        want, res1, res2, snap2, final = run_async(main())
        assert isinstance(res1[0], ServeLaunchFailed)
        assert (np.asarray(res2.grid) == np.asarray(want.grid)).all()
        assert res2.rounds <= 2        # resumed: at most 2 chunks recomputed
        assert_conserved(final)

    def test_checkpointed_request_requires_configured_dir(self):
        async def main():
            svc = await StencilService(service_config()).start(prewarm=False)
            from repro.serve import NoMatchingBucket
            with pytest.raises(NoMatchingBucket, match="checkpoint_dir"):
                svc.submit_nowait(StencilRequest(
                    "diffusion2d", grid_for(0), 4,
                    checkpoint_key="k", checkpoint_every=2))
            await svc.stop()
            return svc.snapshot()
        assert_conserved(run_async(main()))


# --- the chaos matrix --------------------------------------------------------

CHAOS_POINTS = sorted(registered_points())


@pytest.mark.parametrize("point", CHAOS_POINTS)
def test_chaos_matrix_never_hangs_never_drops(point, tmp_path):
    """One transient raise at every registered seam, under a live serving
    workload (including a checkpointed request so the checkpoint seams see
    traffic).  Whatever the seam, the service must answer every request
    (result or typed error) and its books must balance."""
    async def main():
        cfg = service_config(checkpoint_dir=str(tmp_path / "ck"))
        svc = await StencilService(cfg).start(prewarm=False)
        with FaultPlan([FaultSpec(point, nth=1)]).active() as fplan:
            res = await run_workload(svc, n=5, iters=(2, 4))
            futs = svc.submit_nowait(StencilRequest(
                "diffusion2d", grid_for(9), 4,
                checkpoint_key="chaos", checkpoint_every=2))
            res.extend(await asyncio.gather(futs, return_exceptions=True))
            fired = list(fplan.fired)
        await svc.stop()
        return res, fired, svc.snapshot()
    res, fired, snap = run_async(main())
    # every request was answered: a result or a typed serve error
    from repro.serve import ServeError
    for r in res:
        assert not isinstance(r, Exception) or isinstance(r, ServeError), r
    assert_conserved(snap)
    if fired:     # a transient fault at a retried seam must not lose work
        assert snap["completed"] + snap["failed_total"] \
            + snap["rejected_total"] == snap["submitted"]


# --- schedule cache: injected flakiness + the two-process race ---------------

class TestScheduleCacheResilience:
    def test_injected_read_failure_degrades_to_miss(self, tmp_path):
        cache = ScheduleCache(tmp_path / "s.json")
        cache.put("k", {"par_time": 4})
        assert cache.get("k")["par_time"] == 4
        with FaultPlan([FaultSpec("schedule_cache.get",
                                  exc=OSError)]).active():
            assert cache.get("k") is None       # flaky read -> miss, no crash
        assert cache.get("k")["par_time"] == 4  # next read recovers

    def test_injected_write_failure_warns_not_crashes(self, tmp_path):
        cache = ScheduleCache(tmp_path / "s.json")
        with FaultPlan([FaultSpec("schedule_cache.put",
                                  exc=OSError)]).active():
            with pytest.warns(RuntimeWarning, match="not persisted"):
                cache.put("k", {"par_time": 4})
        assert cache.get("k") is None

    def test_two_process_put_race_loses_nothing(self, tmp_path):
        """Regression for the read-modify-write race: two real processes
        hammering put() concurrently must not clobber each other's entries
        (put merges with the on-disk state under an exclusive lock
        immediately before its atomic replace)."""
        path = str(tmp_path / "shared.json")
        script = os.path.join(os.path.dirname(__file__),
                              "schedule_cache_race_check.py")
        count = 40
        procs = [subprocess.Popen(
            [sys.executable, script, path, prefix, str(count)],
            env=subprocess_env(), stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True) for prefix in ("a", "b")]
        for p in procs:
            out, err = p.communicate(timeout=120)
            assert p.returncode == 0, f"stdout:\n{out}\nstderr:\n{err}"
        cache = ScheduleCache(path)
        assert len(cache) == 2 * count
        for prefix in ("a", "b"):
            for i in range(count):
                assert cache.get(f"{prefix}-{i}") is not None, \
                    f"lost entry {prefix}-{i}"


# --- checkpointed long runs --------------------------------------------------

class TestCheckpointedRuns:
    def make_plan(self):
        return plan(StencilProblem("diffusion2d", SHAPE),
                    RunConfig(**RUN))

    def test_chunked_run_is_bit_identical_and_resumes(self, tmp_path):
        p = self.make_plan()
        g = grid_for(3)
        want = np.asarray(p.run(g, 10))
        ckdir = str(tmp_path / "ck")
        res = run_checkpointed(p, g, 10, checkpoint_every=3,
                               checkpoint_dir=ckdir)
        assert (np.asarray(res.grid) == want).all()
        # checkpoint_every=3 aligns up to par_time=2 multiples -> 4
        assert res.checkpoint_every == 4
        assert res.steps_saved == (4, 8, 10) and res.resumed_from == 0
        # wipe the last step: the rerun resumes from 8 and recomputes only
        # the tail — still bit-identical
        import shutil
        shutil.rmtree(os.path.join(ckdir, "step_00000010"))
        res2 = run_checkpointed(p, g, 10, checkpoint_every=3,
                                checkpoint_dir=ckdir)
        assert res2.resumed_from == 8 and res2.chunks_run == 1
        assert (np.asarray(res2.grid) == want).all()
        # fully-final directory: nothing to run
        res3 = run_checkpointed(p, g, 10, checkpoint_every=3,
                                checkpoint_dir=ckdir)
        assert res3.chunks_run == 0
        assert (np.asarray(res3.grid) == want).all()

    def test_plan_run_checkpoint_kwargs(self, tmp_path):
        p = self.make_plan()
        g = grid_for(4)
        want = np.asarray(p.run(g, 6))
        got = p.run(g, 6, checkpoint_every=2,
                    checkpoint_dir=str(tmp_path / "ck"))
        assert (np.asarray(got) == want).all()
        with pytest.raises(ValueError, match="go together"):
            p.run(g, 6, checkpoint_every=2)

    def test_foreign_directory_refused(self, tmp_path):
        p = self.make_plan()
        g = grid_for(5)
        ckdir = str(tmp_path / "ck")
        p.run(g, 4, checkpoint_every=2, checkpoint_dir=ckdir)
        # different iters = a different computation
        with pytest.raises(CheckpointMismatch):
            p.run(g, 6, checkpoint_every=2, checkpoint_dir=ckdir)
        # different problem entirely
        other = plan(StencilProblem("diffusion2d", (8, 32)),
                     RunConfig(**RUN))
        with pytest.raises(CheckpointMismatch):
            other.run(grid_for(5, (8, 32)), 4, checkpoint_every=2,
                      checkpoint_dir=ckdir)

    def test_unhealthy_state_is_never_checkpointed(self, tmp_path):
        p = self.make_plan()
        ckdir = str(tmp_path / "ck")
        # poison the backend's result mid-run: the chunk-boundary health
        # check must raise AND leave no checkpoint of the NaN'd grid
        with FaultPlan([FaultSpec("backend.execute.result",
                                  action="nan")]).active():
            with pytest.raises(NumericalFault):
                run_checkpointed(p, grid_for(6), 4, checkpoint_every=2,
                                 checkpoint_dir=ckdir, health=True)
        from repro.checkpoint import complete_steps
        assert complete_steps(ckdir) == []

    def test_sigkill_mid_save_resumes_bit_identical(self, tmp_path):
        """The acceptance crash test: a real subprocess is SIGKILL'd inside
        its second checkpoint save (shards written, publish rename not yet
        done); rerunning against the same directory resumes from the last
        complete step and finishes bit-identical to a never-killed run."""
        script = os.path.join(os.path.dirname(__file__),
                              "resilience_kill_resume_check.py")
        ckdir = str(tmp_path / "ck")

        fresh = subprocess.run([sys.executable, script, "fresh", ckdir],
                               env=subprocess_env(), capture_output=True,
                               text=True, timeout=300)
        assert fresh.returncode == 0, fresh.stderr
        want = [l for l in fresh.stdout.splitlines()
                if l.startswith("sha256:")][0]

        crash = subprocess.run([sys.executable, script, "crash", ckdir],
                               env=subprocess_env(), capture_output=True,
                               text=True, timeout=300)
        assert crash.returncode == -9, \
            f"expected SIGKILL, got rc={crash.returncode}\n{crash.stderr}"
        # the kill left step 2 published and step 4 as an unpublished .tmp
        assert os.path.isdir(os.path.join(ckdir, "step_00000002"))
        assert os.path.isdir(os.path.join(ckdir, "step_00000004.tmp"))
        assert not os.path.isdir(os.path.join(ckdir, "step_00000004"))

        resume = subprocess.run([sys.executable, script, "resume", ckdir],
                                env=subprocess_env(), capture_output=True,
                                text=True, timeout=300)
        assert resume.returncode == 0, resume.stderr
        lines = resume.stdout.splitlines()
        assert "resumed_from:2" in lines
        got = [l for l in lines if l.startswith("sha256:")][0]
        assert got == want, "resumed final grid differs from uninterrupted run"
